//! Suppression machinery: inline `audit:allow` comments (rule list in
//! parens, then a mandatory reason) and the `rust/audit.toml` baseline.  Both are ratcheted — an allow that
//! no longer suppresses anything, or a baseline entry counting more
//! findings than exist, becomes a diagnostic itself, so debt can only
//! shrink.

use std::collections::BTreeMap;

use crate::analysis::lexer::{Tok, TokKind};
use crate::analysis::rules::Diagnostic;
use crate::util::toml_lite::{self, TomlValue};

/// One parsed inline allow.  Covers findings on its own line and on the
/// line immediately below (so it can sit above the offending expression).
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub rules: Vec<String>,
    pub has_reason: bool,
}

/// Scan the **unstripped** token stream for `audit:allow` comments.
/// Malformed allows (no closing paren, empty rule list, missing reason)
/// surface as `allow-syntax` diagnostics from [`apply_inline`].
pub fn parse_allows(toks: &[Tok]) -> Vec<Allow> {
    const NEEDLE: &str = "audit:allow(";
    let mut allows = Vec::new();
    for t in toks {
        if t.kind != TokKind::Comment {
            continue;
        }
        let Some(pos) = t.text.find(NEEDLE) else {
            continue;
        };
        let rest = &t.text[pos + NEEDLE.len()..];
        let Some(close) = rest.find(')') else {
            allows.push(Allow {
                line: t.line,
                rules: Vec::new(),
                has_reason: false,
            });
            continue;
        };
        let rules: Vec<String> = rest[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let reason = rest[close + 1..].trim().trim_end_matches("*/").trim();
        allows.push(Allow {
            line: t.line,
            rules,
            has_reason: !reason.is_empty(),
        });
    }
    allows
}

/// Split raw findings into (unsuppressed, suppressed) using the file's
/// inline allows, and append the suppression machinery's own diagnostics
/// (`allow-syntax` for malformed allows, `stale-allow` for allows that
/// matched nothing) to the unsuppressed side.
pub fn apply_inline(
    file: &str,
    raw: Vec<Diagnostic>,
    allows: &[Allow],
) -> (Vec<Diagnostic>, Vec<Diagnostic>) {
    let mut unsuppressed = Vec::new();
    let mut suppressed = Vec::new();
    let mut used = vec![false; allows.len()];

    for d in raw {
        let matched = allows.iter().enumerate().find(|(_, a)| {
            a.rules.iter().any(|r| r == &d.rule) && (d.line == a.line || d.line == a.line + 1)
        });
        match matched {
            Some((ai, a)) => {
                if !a.has_reason {
                    unsuppressed.push(Diagnostic {
                        file: file.to_string(),
                        line: a.line,
                        rule: "allow-syntax".into(),
                        message: "audit:allow without a reason".into(),
                    });
                }
                used[ai] = true;
                suppressed.push(d);
            }
            None => unsuppressed.push(d),
        }
    }
    for (ai, a) in allows.iter().enumerate() {
        if a.rules.is_empty() {
            unsuppressed.push(Diagnostic {
                file: file.to_string(),
                line: a.line,
                rule: "allow-syntax".into(),
                message: "malformed audit:allow (empty or unclosed rule list)".into(),
            });
        } else if !used[ai] {
            unsuppressed.push(Diagnostic {
                file: file.to_string(),
                line: a.line,
                rule: "stale-allow".into(),
                message: format!("allow({}) suppresses nothing", a.rules.join(",")),
            });
        }
    }
    (unsuppressed, suppressed)
}

/// The `audit.toml` baseline: `<rule>@<relpath> = <count>` entries
/// granting a file a fixed budget of findings for one rule.  Parsed with
/// the crate's own `toml_lite`.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    /// (rule, file) -> allowed count.
    entries: BTreeMap<(String, String), usize>,
}

impl Baseline {
    pub fn parse(text: &str) -> Result<Self, String> {
        let map = toml_lite::parse(text).map_err(|e| format!("audit baseline: {e}"))?;
        let mut entries = BTreeMap::new();
        for (key, val) in map {
            let Some((rule, file)) = key.split_once('@') else {
                return Err(format!("audit baseline: key {key:?} is not <rule>@<path>"));
            };
            let TomlValue::Num(n) = val else {
                return Err(format!("audit baseline: {key:?} must be an integer count"));
            };
            if n.fract() != 0.0 || n < 0.0 {
                return Err(format!("audit baseline: {key:?} must be a non-negative integer"));
            }
            entries.insert((rule.trim().to_string(), file.trim().to_string()), n as usize);
        }
        Ok(Self { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Apply the baseline to the remaining unsuppressed findings.  A
    /// (rule, file) group with `count <= budget` is suppressed wholesale;
    /// a budget that exceeds the actual count adds a `stale-baseline`
    /// diagnostic (the ratchet: shrink the entry when you fix a finding).
    /// A group over budget stays fully unsuppressed — partial credit
    /// would make the report depend on finding order.
    pub fn apply(
        &self,
        unsuppressed: Vec<Diagnostic>,
        suppressed: &mut Vec<Diagnostic>,
    ) -> Vec<Diagnostic> {
        let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        for d in &unsuppressed {
            *counts.entry((d.rule.clone(), d.file.clone())).or_insert(0) += 1;
        }
        let mut out = Vec::new();
        for d in unsuppressed {
            let key = (d.rule.clone(), d.file.clone());
            let budget = self.entries.get(&key).copied().unwrap_or(0);
            let count = counts.get(&key).copied().unwrap_or(0);
            if budget >= count && budget > 0 {
                suppressed.push(d);
            } else {
                out.push(d);
            }
        }
        for ((rule, file), budget) in &self.entries {
            let count = counts.get(&(rule.clone(), file.clone())).copied().unwrap_or(0);
            if *budget > count {
                out.push(Diagnostic {
                    file: file.clone(),
                    line: 0,
                    rule: "stale-baseline".into(),
                    message: format!(
                        "baseline grants {budget} `{rule}` finding(s) but only {count} exist; shrink the entry"
                    ),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn diag(file: &str, line: u32, rule: &str) -> Diagnostic {
        Diagnostic {
            file: file.into(),
            line,
            rule: rule.into(),
            message: "m".into(),
        }
    }

    #[test]
    fn allow_covers_own_and_next_line() {
        let toks = lex("// audit:allow(lossy-cast) guarded above\nlet a = 0.5 as usize;");
        let allows = parse_allows(&toks);
        assert_eq!(allows.len(), 1);
        let (uns, sup) = apply_inline("f.rs", vec![diag("f.rs", 2, "lossy-cast")], &allows);
        assert!(uns.is_empty(), "{uns:?}");
        assert_eq!(sup.len(), 1);
    }

    #[test]
    fn allow_without_reason_is_flagged() {
        let toks = lex("let a = 0.5 as usize; // audit:allow(lossy-cast)");
        let allows = parse_allows(&toks);
        let (uns, sup) = apply_inline("f.rs", vec![diag("f.rs", 1, "lossy-cast")], &allows);
        assert_eq!(sup.len(), 1);
        assert_eq!(uns.len(), 1);
        assert_eq!(uns[0].rule, "allow-syntax");
    }

    #[test]
    fn stale_allow_is_flagged() {
        let toks = lex("// audit:allow(nan-cmp) nothing here anymore\nlet a = 1;");
        let allows = parse_allows(&toks);
        let (uns, _) = apply_inline("f.rs", Vec::new(), &allows);
        assert_eq!(uns.len(), 1);
        assert_eq!(uns[0].rule, "stale-allow");
    }

    #[test]
    fn wrong_rule_does_not_suppress() {
        let toks = lex("// audit:allow(nan-cmp) wrong rule\nlet a = 0.5 as usize;");
        let allows = parse_allows(&toks);
        let (uns, sup) = apply_inline("f.rs", vec![diag("f.rs", 2, "lossy-cast")], &allows);
        assert_eq!(sup.len(), 0);
        // the lossy-cast finding survives AND the allow is stale
        assert_eq!(uns.len(), 2);
    }

    #[test]
    fn baseline_roundtrip_and_stale_detection() {
        let b = Baseline::parse("lossy-cast@src/a.rs = 2\nnan-cmp@src/b.rs = 1\n").unwrap();
        let mut sup = Vec::new();
        let uns = b.apply(
            vec![
                diag("src/a.rs", 3, "lossy-cast"),
                diag("src/a.rs", 9, "lossy-cast"),
            ],
            &mut sup,
        );
        assert_eq!(sup.len(), 2);
        // nan-cmp budget is unused -> stale-baseline
        assert_eq!(uns.len(), 1);
        assert_eq!(uns[0].rule, "stale-baseline");
        assert!(uns[0].message.contains("only 0 exist"));
    }

    #[test]
    fn baseline_over_budget_stays_unsuppressed() {
        let b = Baseline::parse("lossy-cast@src/a.rs = 1\n").unwrap();
        let mut sup = Vec::new();
        let uns = b.apply(
            vec![
                diag("src/a.rs", 3, "lossy-cast"),
                diag("src/a.rs", 9, "lossy-cast"),
            ],
            &mut sup,
        );
        assert!(sup.is_empty());
        assert_eq!(uns.len(), 2);
    }

    #[test]
    fn bad_baseline_keys_error() {
        assert!(Baseline::parse("no_at_sign = 1").is_err());
        assert!(Baseline::parse("lossy-cast@f.rs = 1.5").is_err());
    }
}
