//! Comment- and string-aware Rust token scanner for the audit pass.
//!
//! This is deliberately **not** a full Rust lexer — it is the smallest
//! token model under which the audit rules cannot be fooled by surface
//! syntax: comments (line + nested block), every string flavor
//! (`"…"`, `b"…"`, `c"…"`, raw `r"…"`/`r#"…"#`/`br…`/`cr…`), char
//! literals vs. lifetimes (`'a'` vs. `'a`), numbers with type suffixes,
//! raw identifiers (`r#match`) and single-byte punctuation.  Anything a
//! rule matches on is a real code token, never text inside a string or a
//! comment — the failure mode that makes grep-based lint scripts lie.
//!
//! The scanner is byte-oriented: all token *boundaries* are ASCII, so
//! multi-byte UTF-8 only ever occurs inside string/comment token bodies
//! (or as standalone punct bytes, which no rule matches on).

/// Token class. `Comment` tokens are kept (the suppression parser reads
/// them); rules run on the comment-stripped stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
    Comment,
}

/// One scanned token with its 1-based source line (the line the token
/// *starts* on, for multi-line strings and block comments).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    fn new(kind: TokKind, bytes: &[u8], line: u32) -> Self {
        Self {
            kind,
            text: String::from_utf8_lossy(bytes).into_owned(),
            line,
        }
    }

    /// Convenience for the rule matchers.
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

#[inline]
fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn count_newlines(bytes: &[u8]) -> u32 {
    bytes.iter().filter(|&&b| b == b'\n').count() as u32
}

/// Scan past a `"…"` body starting at the opening quote; returns the index
/// one past the closing quote (or `len` on an unterminated string).
fn scan_str(src: &[u8], open: usize) -> usize {
    let mut j = open + 1;
    while j < src.len() {
        match src[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    src.len()
}

/// Scan past a `'…'` char literal starting at the quote (handles escapes
/// including `\u{…}`); returns the index one past the closing quote.
fn scan_char(src: &[u8], open: usize) -> usize {
    let n = src.len();
    let mut j = open + 1;
    if j < n && src[j] == b'\\' {
        j += 2;
        if j <= n && j >= 1 && matches!(src[j - 1], b'u' | b'U') && j < n && src[j] == b'{' {
            while j < n && src[j] != b'}' {
                j += 1;
            }
            j += 1;
        }
        while j < n && src[j] != b'\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    while j < n && src[j] != b'\'' {
        j += 1;
    }
    (j + 1).min(n)
}

/// Find `pattern` in `src[from..]`; returns an absolute index or `None`.
fn find_from(src: &[u8], from: usize, pattern: &[u8]) -> Option<usize> {
    if pattern.is_empty() || from > src.len() {
        return None;
    }
    src[from..]
        .windows(pattern.len())
        .position(|w| w == pattern)
        .map(|p| from + p)
}

/// Tokenize `src`. Never fails: unterminated constructs swallow the rest
/// of the file as a single token (the audit then sees exactly what rustc
/// would reject anyway).
pub fn lex(src: &str) -> Vec<Tok> {
    let src = src.as_bytes();
    let n = src.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;

    while i < n {
        let c = src[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if src[i..].starts_with(b"//") {
            let j = find_from(src, i, b"\n").unwrap_or(n);
            toks.push(Tok::new(TokKind::Comment, &src[i..j], line));
            i = j;
            continue;
        }
        // nested block comment
        if src[i..].starts_with(b"/*") {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if src[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    if src[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok::new(TokKind::Comment, &src[i..j], start_line));
            i = j;
            continue;
        }
        // raw strings (r / br / cr with optional hashes), b/c strings,
        // byte chars and raw idents all start with one of r, b, c.
        if matches!(c, b'r' | b'b' | b'c') {
            let rpos = if c == b'r' {
                Some(i + 1)
            } else if src[i..].starts_with(b"br") || src[i..].starts_with(b"cr") {
                Some(i + 2)
            } else {
                None
            };
            if let Some(rpos) = rpos {
                let mut h = rpos;
                while h < n && src[h] == b'#' {
                    h += 1;
                }
                if h < n && src[h] == b'"' {
                    // raw string: ends at `"` followed by the same number
                    // of hashes
                    let mut close = vec![b'"'];
                    close.extend(std::iter::repeat(b'#').take(h - rpos));
                    let j = match find_from(src, h + 1, &close) {
                        Some(p) => p + close.len(),
                        None => n,
                    };
                    let start_line = line;
                    line += count_newlines(&src[i..j]);
                    toks.push(Tok::new(TokKind::Str, &src[i..j], start_line));
                    i = j;
                    continue;
                }
            }
            if matches!(c, b'b' | b'c') && i + 1 < n && src[i + 1] == b'"' {
                let j = scan_str(src, i + 1);
                let start_line = line;
                line += count_newlines(&src[i..j]);
                toks.push(Tok::new(TokKind::Str, &src[i..j], start_line));
                i = j;
                continue;
            }
            if c == b'b' && i + 1 < n && src[i + 1] == b'\'' {
                let j = scan_char(src, i + 1);
                toks.push(Tok::new(TokKind::Char, &src[i..j], line));
                i = j;
                continue;
            }
            if c == b'r'
                && src[i..].starts_with(b"r#")
                && i + 2 < n
                && (src[i + 2].is_ascii_alphabetic() || src[i + 2] == b'_')
            {
                // raw identifier r#foo
                let mut j = i + 2;
                while j < n && is_ident_byte(src[j]) {
                    j += 1;
                }
                toks.push(Tok::new(TokKind::Ident, &src[i..j], line));
                i = j;
                continue;
            }
            // plain ident starting with r/b/c — fall through to the ident
            // arm below.
        }
        if c == b'"' {
            let j = scan_str(src, i);
            let start_line = line;
            line += count_newlines(&src[i..j]);
            toks.push(Tok::new(TokKind::Str, &src[i..j], start_line));
            i = j;
            continue;
        }
        if c == b'\'' {
            // disambiguate char literal from lifetime
            if i + 1 < n && src[i + 1] == b'\\' {
                let j = scan_char(src, i);
                toks.push(Tok::new(TokKind::Char, &src[i..j], line));
                i = j;
                continue;
            }
            if i + 2 < n && src[i + 2] == b'\'' {
                toks.push(Tok::new(TokKind::Char, &src[i..i + 3], line));
                i += 3;
                continue;
            }
            // lifetime: `'` + ident chars (possibly empty — stray quote)
            let mut j = i + 1;
            while j < n && is_ident_byte(src[j]) {
                j += 1;
            }
            toks.push(Tok::new(TokKind::Lifetime, &src[i..j], line));
            i = j.max(i + 1);
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            if src[i..].starts_with(b"0x") || src[i..].starts_with(b"0b") || src[i..].starts_with(b"0o") {
                j = i + 2;
                while j < n && is_ident_byte(src[j]) {
                    j += 1;
                }
            } else {
                while j < n && (src[j].is_ascii_digit() || src[j] == b'_') {
                    j += 1;
                }
                if j + 1 < n && src[j] == b'.' && src[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < n && (src[j].is_ascii_digit() || src[j] == b'_') {
                        j += 1;
                    }
                }
                if j < n
                    && matches!(src[j], b'e' | b'E')
                    && (j + 1 < n
                        && (src[j + 1].is_ascii_digit()
                            || (matches!(src[j + 1], b'+' | b'-')
                                && j + 2 < n
                                && src[j + 2].is_ascii_digit())))
                {
                    j += 2;
                    while j < n && src[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                // type suffix (f64, u32, …) glued to the literal
                while j < n && is_ident_byte(src[j]) {
                    j += 1;
                }
            }
            toks.push(Tok::new(TokKind::Num, &src[i..j], line));
            i = j;
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && is_ident_byte(src[j]) {
                j += 1;
            }
            toks.push(Tok::new(TokKind::Ident, &src[i..j], line));
            i = j;
            continue;
        }
        toks.push(Tok::new(TokKind::Punct, &src[i..i + 1], line));
        i += 1;
    }
    toks
}

/// The comment-stripped stream the rules run on.
pub fn code_tokens(toks: &[Tok]) -> Vec<Tok> {
    toks.iter().filter(|t| t.kind != TokKind::Comment).cloned().collect()
}

/// Index of the `}` matching the `{` at `i_open` (token indices).
pub fn match_brace(toks: &[Tok], i_open: usize) -> usize {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(i_open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Index of the `(` matching the `)` at `i_close`, scanning backwards.
pub fn match_paren_back(toks: &[Tok], i_close: usize) -> usize {
    let mut depth = 0i64;
    for k in (0..=i_close).rev() {
        if toks[k].kind == TokKind::Punct {
            match toks[k].text.as_str() {
                ")" => depth += 1,
                "(" => {
                    depth -= 1;
                    if depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    0
}

/// Index of the `)` matching the `(` at `i_open`.
pub fn match_paren_fwd(toks: &[Tok], i_open: usize) -> usize {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(i_open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn line_and_block_comments_are_single_tokens() {
        let ts = kinds("a // unwrap() here\nb /* x /* nested */ still comment */ c");
        let idents: Vec<_> = ts.iter().filter(|(k, _)| *k == TokKind::Ident).collect();
        assert_eq!(idents.len(), 3);
        let comments: Vec<_> = ts.iter().filter(|(k, _)| *k == TokKind::Comment).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[1].1.contains("nested"));
    }

    #[test]
    fn raw_strings_hide_their_contents() {
        let ts = kinds(r####"let x = r#"Instant::now() . "quoted" "#; y"####);
        let strs: Vec<_> = ts.iter().filter(|(k, _)| *k == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].1.contains("Instant::now"));
        // the ident stream must NOT contain Instant
        assert!(!ts
            .iter()
            .any(|(k, t)| *k == TokKind::Ident && t == "Instant"));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "y"));
    }

    #[test]
    fn byte_and_c_strings() {
        let ts = kinds(r#"let a = b"bytes"; let b2 = c"cstr"; let c2 = br"raw";"#);
        let strs: Vec<_> = ts.iter().filter(|(k, _)| *k == TokKind::Str).collect();
        assert_eq!(strs.len(), 3);
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let ts = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let esc = '\\n'; }");
        assert_eq!(
            ts.iter().filter(|(k, _)| *k == TokKind::Lifetime).count(),
            2
        );
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Char).count(), 2);
    }

    #[test]
    fn numbers_with_suffixes_and_floats() {
        let ts = kinds("let a = 1_000f64; let b = 0.95; let c = 1e-9; let d = 0xFFu32;");
        let nums: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["1_000f64", "0.95", "1e-9", "0xFFu32"]);
    }

    #[test]
    fn raw_ident_is_one_token() {
        let ts = kinds("let r#match = 1;");
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "r#match"));
    }

    #[test]
    fn multiline_string_line_numbers() {
        let toks = lex("let a = \"x\ny\";\nlet b = 1;");
        let b = toks.iter().find(|t| t.is(TokKind::Ident, "b")).unwrap();
        assert_eq!(b.line, 3);
    }

    #[test]
    fn paren_and_brace_matching() {
        let toks = code_tokens(&lex("f(a, (b), c) { { } }"));
        let open = toks.iter().position(|t| t.is(TokKind::Punct, "(")).unwrap();
        let close = match_paren_fwd(&toks, open);
        assert!(toks[close].is(TokKind::Punct, ")"));
        assert_eq!(match_paren_back(&toks, close), open);
        let brace = toks.iter().position(|t| t.is(TokKind::Punct, "{")).unwrap();
        let end = match_brace(&toks, brace);
        assert_eq!(end, toks.len() - 1);
    }
}
