//! Offline stub of the xla/PJRT bindings (see README.md).
//!
//! Only the surface consumed by `jdob::runtime::executor` is provided.
//! Host-side `Literal` operations are real; anything that needs an actual
//! PJRT runtime returns [`Error::Unavailable`] so callers fail fast with an
//! actionable message instead of segfaulting into a missing toolchain.

use std::fmt;

/// Stub error: every device-side entry point produces `Unavailable`.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} unavailable — this build links the offline stub; \
                 point the `xla` dependency at a real PJRT binding (rust/vendor/xla/README.md) \
                 or use the default SimBackend"
            ),
            Error::Shape(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl NativeType for f64 {
    fn from_f32(v: f32) -> Self {
        v as f64
    }
}

/// Host-side tensor: flat f32 data plus dims. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Self {
        Self {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "reshape to {:?} ({} elems) from {} elems",
                dims,
                count,
                self.data.len()
            )));
        }
        Ok(Self {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unwrap a 1-tuple result (identity in the stub).
    pub fn to_tuple1(self) -> Result<Self> {
        Ok(self)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }
}

/// Parsed HLO module (opaque in the stub; parsing needs real XLA).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

pub struct Device {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn devices(&self) -> Vec<Device> {
        Vec::new()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&Device>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn device_paths_fail_fast() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
    }
}
