//! END-TO-END driver (DESIGN.md §6): a fleet of simulated mobile devices
//! submits real image-classification requests to the threaded coordinator,
//! which groups them (OG), plans (J-DOB), and executes on the build's
//! inference backend: device-side prefixes at b=1, uplink per the channel
//! model, edge tails batch-executed at the planned batch size.  Reports
//! per-request latency, deadline hit-rate, modeled energy and throughput —
//! recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example multiuser_serving` (deterministic
//! SimBackend; with `--features pjrt` + `make artifacts` it executes the
//! AOT artifacts through PJRT instead).
//! Options: --users M --rounds R --beta B --solver NAME

use std::time::{Duration, Instant};

use jdob::algo::types::{PlanningContext, User};
use jdob::coordinator::metrics::LatencySummary;
use jdob::coordinator::request::InferenceRequest;
use jdob::coordinator::server::{start, WindowPolicy};
use jdob::energy::device::DeviceModel;
use jdob::util::cli::Args;
use jdob::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let m = args.get_usize("users", 8)?;
    let rounds = args.get_usize("rounds", 3)?;
    let beta = args.get_f64("beta", 30.25)?;
    let solver: &'static str = match args.get_str("solver", "J-DOB") {
        "LC" => "LC",
        "IP-SSA" => "IP-SSA",
        "J-DOB w/o edge DVFS" => "J-DOB w/o edge DVFS",
        "J-DOB binary" => "J-DOB binary",
        _ => "J-DOB",
    };

    let ctx = PlanningContext::default_analytic();
    let artifacts = std::path::PathBuf::from(
        args.get_str("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
    );

    let dev = DeviceModel::from_config(&ctx.cfg);
    let deadline_s = User::deadline_from_beta(beta, &dev, ctx.tables.total_work());
    let elems: usize = ctx.profile.input_shape.iter().product();
    println!(
        "serving {} users x {} rounds with {} (beta = {beta}, deadline = {:.0} ms)",
        m, rounds, solver, deadline_s * 1e3
    );

    let policy = WindowPolicy {
        max_batch: m,
        max_wait: Duration::from_millis(100),
    };
    let (handle, join) = start(ctx.clone(), artifacts, solver, policy);

    let mut wall = LatencySummary::default();
    let mut modeled = LatencySummary::default();
    let mut hits = 0usize;
    let mut total = 0usize;
    let mut offloaded = 0usize;
    let t_run = Instant::now();

    for round in 0..rounds {
        // every device builds its own synthetic image
        let mut rng = Rng::seed_from_u64(round as u64);
        let rxs: Vec<_> = (0..m)
            .map(|u| {
                let input: Vec<f32> = (0..elems).map(|_| rng.gen_range(-0.5, 0.5) as f32).collect();
                let t0 = Instant::now();
                let rx = handle
                    .submit_async(InferenceRequest {
                        user_id: u,
                        input,
                        deadline_s: deadline_s,
                    })
                    .expect("submit");
                (u, t0, rx)
            })
            .collect();
        for (u, t0, rx) in rxs {
            let resp = rx.recv().expect("reply").map_err(anyhow::Error::msg)?;
            wall.record(t0.elapsed());
            modeled.record_s(resp.modeled_latency_s);
            total += 1;
            hits += resp.deadline_met as usize;
            offloaded += resp.offloaded as usize;
            if round == 0 && u == 0 {
                println!(
                    "  first request: class {} | modeled {:.1} ms | wall {:.1} ms | ñ={} | {}",
                    resp.argmax(),
                    resp.modeled_latency_s * 1e3,
                    t0.elapsed().as_secs_f64() * 1e3,
                    resp.partition,
                    if resp.offloaded { "offloaded" } else { "local" }
                );
            }
        }
        println!("round {round} done ({} requests served)", (round + 1) * m);
    }
    drop(handle);
    let ledger = join.join().expect("leader").expect("leader ok");
    let span = t_run.elapsed().as_secs_f64();

    println!("\n=== serving report ({} requests) ===", total);
    println!(
        "  deadline hit rate  : {:.1}% ({} of {})",
        100.0 * hits as f64 / total as f64,
        hits,
        total
    );
    println!("  offloaded          : {offloaded} of {total}");
    println!(
        "  modeled latency    : p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms",
        modeled.p50() * 1e3,
        modeled.p95() * 1e3,
        modeled.max() * 1e3
    );
    println!(
        "  wall latency       : p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms (includes first-use backend warmup)",
        wall.p50() * 1e3,
        wall.p95() * 1e3,
        wall.max() * 1e3
    );
    println!(
        "  energy             : device {:.2} mJ + tx {:.2} mJ + edge {:.2} mJ = {:.2} mJ/user",
        ledger.device_compute_j * 1e3,
        ledger.device_tx_j * 1e3,
        ledger.edge_j * 1e3,
        ledger.per_user_j() * 1e3
    );
    println!("  throughput         : {:.1} req/s over {:.2} s wall", total as f64 / span, span);
    anyhow::ensure!(hits == total, "deadline misses in a feasible scenario");
    Ok(())
}
