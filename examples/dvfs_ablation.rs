//! Ablation study: what each ingredient of J-DOB is worth.
//!
//! Sweeps beta and M, comparing full J-DOB against its published ablations
//! (no edge DVFS; binary offloading) plus LC, and reports where each
//! ingredient matters most — the quantitative version of the paper's
//! "edge DVFS is a crucial optimization dimension" claim.
//!
//! Run: `cargo run --release --example dvfs_ablation`

use jdob::algo::baselines::LocalComputing;
use jdob::algo::jdob::JDob;
use jdob::algo::types::{GroupSolver, PlanningContext};
use jdob::sim::experiments::compare_solvers;

fn main() -> anyhow::Result<()> {
    let ctx = PlanningContext::default_analytic();
    let full = JDob::full();
    let no_edge = JDob::without_edge_dvfs();
    let binary = JDob::binary_offloading();
    let lc = LocalComputing;
    let solvers: Vec<&dyn GroupSolver> = vec![&lc, &no_edge, &binary, &full];
    let counts = [1usize, 2, 4, 8, 16, 30];

    for beta in [0.5, 2.13, 8.0, 30.25] {
        println!("=== beta = {beta} ===");
        let rows = compare_solvers(&ctx, &solvers, &counts, beta);
        print!("{:>4}", "M");
        for (name, _) in &rows[0].series {
            print!("{:>24}", name);
        }
        println!("{:>18}{:>18}", "eDVFS gain", "partial gain");
        for row in &rows {
            print!("{:>4}", row.x as usize);
            for (_, e) in &row.series {
                print!("{:>21.2} mJ", e * 1e3);
            }
            let get = |n: &str| row.series.iter().find(|(s, _)| s == n).unwrap().1;
            let edvfs_gain = 1.0 - get("J-DOB") / get("J-DOB w/o edge DVFS");
            let partial_gain = 1.0 - get("J-DOB") / get("J-DOB binary");
            println!("{:>17.1}%{:>17.1}%", edvfs_gain * 100.0, partial_gain * 100.0);
        }
        println!();
    }

    println!("(eDVFS gain: energy saved by sweeping f_e instead of pinning f_e,max;");
    println!(" partial gain: energy saved by intermediate partition points vs ñ ∈ {{0, N}}.)");
    Ok(())
}
