//! Heterogeneous fleet study (extension beyond Table I's homogeneous
//! devices): users differ in uplink rate and chip efficiency (κ_m). Shows
//! who J-DOB chooses to offload — devices with fast links and hungry chips
//! go first — and how much the fleet saves vs forcing a uniform policy.
//!
//! Run: `cargo run --release --example heterogeneous_fleet`

use jdob::algo::baselines::LocalComputing;
use jdob::algo::jdob::JDob;
use jdob::algo::types::{PlanningContext, User};
use jdob::sim::scenario::heterogeneous_users;
use jdob::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ctx = PlanningContext::default_analytic();
    let mut rng = Rng::seed_from_u64(2025);
    let users: Vec<User> = heterogeneous_users(&ctx, 10, (4.0, 8.0), &mut rng);

    println!("fleet (beta ~ U[4,8], rate x U[0.5,2), kappa x U[0.7,1.3)):");
    for u in &users {
        println!(
            "  user {}: deadline {:>5.0} ms, uplink {:>6.1} Mbit/s, kappa {:.2}x",
            u.id,
            u.deadline_s * 1e3,
            u.dev.rate_bps / 1e6,
            u.dev.kappa / 1e-28
        );
    }

    let plan = JDob::full().solve(&ctx, &users, 0.0).expect("feasible");
    let lc = LocalComputing::solve(&ctx, &users, 0.0).expect("lc");
    println!(
        "\nJ-DOB: ñ = {}, batch = {}, f_e = {:.2} GHz — {:.2} mJ/user vs LC {:.2} mJ/user ({:.1}% saved)",
        plan.partition,
        plan.batch_size,
        plan.f_edge_hz / 1e9,
        plan.energy_per_user_j() * 1e3,
        lc.energy_per_user_j() * 1e3,
        100.0 * (1.0 - plan.total_energy_j / lc.total_energy_j)
    );
    println!("\nper-user decisions (offloaders should skew to fast links / hungry chips):");
    for (u, up) in users.iter().zip(&plan.users) {
        println!(
            "  user {}: {:<8} f_m = {:.2} GHz, {:>6.2} mJ  (uplink {:>6.1} Mbit/s, kappa {:.2}x)",
            u.id,
            if up.offloaded { "OFFLOAD" } else { "local" },
            up.f_dev_hz / 1e9,
            up.device_energy_j() * 1e3,
            u.dev.rate_bps / 1e6,
            u.dev.kappa / 1e-28
        );
    }

    // sanity: every user meets its deadline
    for (u, up) in users.iter().zip(&plan.users) {
        anyhow::ensure!(up.finish_time_s <= u.deadline_s + 1e-9, "user {} misses", u.id);
    }
    println!("\nall deadlines met.");
    Ok(())
}
