//! Different-deadlines scenario (the paper's Fig. 5 setting, §IV-B):
//! users draw beta from widening uniform ranges; the OG dynamic program
//! groups them and J-DOB (or any benchmark) plans each group with the GPU
//! handed off group-to-group.
//!
//! Run: `cargo run --release --example deadline_sweep -- --users 10 --trials 10`

use jdob::algo::grouping::optimal_grouping;
use jdob::algo::jdob::JDob;
use jdob::algo::types::PlanningContext;
use jdob::sim::experiments::{fig5_different_deadlines, max_reduction_vs_lc};
use jdob::sim::scenario::uniform_beta_users;
use jdob::util::cli::Args;
use jdob::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let m = args.get_usize("users", 10)?;
    let trials = args.get_usize("trials", 10)?;

    let ctx = PlanningContext::default_analytic();

    // One representative draw: show the grouping structure itself.
    let mut rng = Rng::seed_from_u64(42);
    let users = uniform_beta_users(&ctx, m, (0.0, 10.0), &mut rng);
    let gp = optimal_grouping(&ctx, &users, &JDob::full(), 0.0).expect("feasible");
    println!("example draw (beta ~ U[0,10], M = {m}): {} groups", gp.groups.len());
    for (gi, (members, plan)) in gp.groups.iter().enumerate() {
        let betas: Vec<String> = members
            .iter()
            .map(|&i| format!("{:.1}", users[i].beta(ctx.tables.total_work())))
            .collect();
        println!(
            "  group {gi}: users {members:?} (beta {}) -> ñ={}, B_o={}, f_e={:.2} GHz, E={:.1} mJ, GPU until {:.0} ms",
            betas.join("/"),
            plan.partition,
            plan.batch_size,
            plan.f_edge_hz / 1e9,
            plan.total_energy_j * 1e3,
            plan.t_free_end_s * 1e3
        );
    }

    // The Fig. 5 sweep proper.
    println!("\nFig. 5 sweep (M = {m}, {trials} trials/range):");
    let ranges = [(4.5, 5.5), (2.0, 8.0), (0.0, 10.0)];
    let rows = fig5_different_deadlines(&ctx, m, &ranges, trials, 0xBEEF);
    print!("{:>12}", "beta range");
    for (name, _) in &rows[0].series {
        print!("{:>24}", name);
    }
    println!();
    for (row, range) in rows.iter().zip(&ranges) {
        print!("{:>12}", format!("[{},{}]", range.0, range.1));
        for (_, e) in &row.series {
            print!("{:>21.2} mJ", e * 1e3);
        }
        println!();
    }
    println!(
        "\nmax J-DOB reduction vs LC: {:.2}% (paper reports up to 45.27% at M=10)",
        max_reduction_vs_lc(&rows, "J-DOB") * 100.0
    );
    Ok(())
}
