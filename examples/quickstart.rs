//! Quickstart: plan a multiuser co-inference group with J-DOB and inspect
//! the strategy. No artifacts needed — planning runs on the analytic
//! Table-I edge model.
//!
//! Run: `cargo run --release --example quickstart`

use jdob::algo::baselines::roster;
use jdob::algo::jdob::JDob;
use jdob::algo::types::{PlanningContext, User};
use jdob::energy::device::DeviceModel;

fn main() -> anyhow::Result<()> {
    // 1. Build the planning context: Table-I config, MobileNetV2@96 profile,
    //    RTX3090-shaped analytic edge model.
    let ctx = PlanningContext::default_analytic();
    println!(
        "model: {} ({} sub-tasks, {:.1} MFLOPs total)",
        ctx.profile.model,
        ctx.n(),
        ctx.profile.total_work() / 1e6
    );

    // 2. Eight users sharing the paper's beta = 2.13 deadline tightness.
    let dev = DeviceModel::from_config(&ctx.cfg);
    let deadline_s = User::deadline_from_beta(2.13, &dev, ctx.tables.total_work());
    let users: Vec<User> = (0..8)
        .map(|id| User {
            id,
            deadline_s,
            dev: dev.clone(),
        })
        .collect();
    println!("group: M = {}, deadline = {:.1} ms\n", users.len(), deadline_s * 1e3);

    // 3. Solve with J-DOB (Algorithm 1 + 2).
    let plan = JDob::full()
        .solve(&ctx, &users, /* GPU free at */ 0.0)
        .expect("paper-conforming groups are always feasible");

    println!("J-DOB strategy:");
    println!("  partition point ñ = {} (blocks 1..{} local, rest at edge)", plan.partition, plan.partition);
    println!("  offloading set    = {:?} (batch size {})", plan.offload_ids(), plan.batch_size);
    println!("  edge frequency    = {:.2} GHz", plan.f_edge_hz / 1e9);
    for up in &plan.users {
        println!(
            "    user {}: {} @ {:.2} GHz, energy {:.2} mJ, finishes at {:.1} ms",
            up.id,
            if up.offloaded { "offload" } else { "local  " },
            up.f_dev_hz / 1e9,
            up.device_energy_j() * 1e3,
            up.finish_time_s * 1e3
        );
    }
    println!(
        "  total energy {:.2} mJ ({:.2} mJ/user), edge {:.2} mJ, GPU busy until {:.1} ms\n",
        plan.total_energy_j * 1e3,
        plan.energy_per_user_j() * 1e3,
        plan.edge_energy_j * 1e3,
        plan.t_free_end_s * 1e3
    );

    // 4. Compare the full benchmark roster.
    println!("benchmarks (same group):");
    for solver in roster() {
        match solver.solve(&ctx, &users, 0.0) {
            Some(p) => println!(
                "  {:<22} {:>8.2} mJ/user  (ñ={}, B_o={})",
                solver.name(),
                p.energy_per_user_j() * 1e3,
                p.partition,
                p.batch_size
            ),
            None => println!("  {:<22} infeasible", solver.name()),
        }
    }
    Ok(())
}
