//! Online scenario (the paper's §V future work): Poisson request arrivals,
//! windowed admission through the shared scheduler core, J-DOB planning per
//! window with the GPU-busy horizon carried across windows — virtual-time
//! simulation comparing J-DOB against local computing under increasing
//! load, then comparing admission policies under deadline pressure.
//!
//! Run: `cargo run --release --example online_serving -- --rate 40 --horizon 10`

use jdob::algo::baselines::LocalComputing;
use jdob::algo::jdob::JDob;
use jdob::algo::types::PlanningContext;
use jdob::sched::admission::{AdmissionPolicy, EarliestSlack, SizeBound, TimeBound};
use jdob::sim::experiments::online_policy_sweep;
use jdob::sim::online::{poisson_arrivals, run_online};
use jdob::util::cli::Args;
use jdob::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let horizon = args.get_f64("horizon", 10.0)?;
    let window_ms = args.get_f64("window-ms", 100.0)?;
    let beta_lo = args.get_f64("beta-lo", 8.0)?;
    let beta_hi = args.get_f64("beta-hi", 25.0)?;
    let seed = args.get_usize("seed", 7)? as u64;

    let ctx = PlanningContext::default_analytic();
    println!(
        "online co-inference: horizon {horizon}s, window {window_ms}ms, beta ~ U[{beta_lo},{beta_hi}]"
    );
    println!(
        "{:>10} {:>9} {:>10} {:>12} {:>12} {:>10} {:>9} {:>10}",
        "rate(req/s)", "requests", "windows", "J-DOB mJ/req", "LC mJ/req", "saving", "hit rate", "offloaded"
    );

    for rate in [5.0, 10.0, 20.0, 40.0, 80.0] {
        let mut rng = Rng::seed_from_u64(seed);
        let arrivals = poisson_arrivals(&ctx, rate, horizon, (beta_lo, beta_hi), &mut rng)?;
        let jd = run_online(&ctx, &arrivals, &JDob::full(), window_ms / 1e3);
        let lc = run_online(&ctx, &arrivals, &LocalComputing, window_ms / 1e3);
        println!(
            "{:>10.0} {:>9} {:>10} {:>12.3} {:>12.3} {:>9.1}% {:>8.1}% {:>9.1}%",
            rate,
            jd.served,
            jd.windows,
            jd.energy_per_user_j() * 1e3,
            lc.energy_per_user_j() * 1e3,
            100.0 * (1.0 - jd.energy_per_user_j() / lc.energy_per_user_j()),
            100.0 * jd.hit_rate(),
            100.0 * jd.offloaded as f64 / jd.served.max(1) as f64,
        );
    }
    println!("\nhigher arrival rates widen the effective batch per window — the online analogue");
    println!("of Fig. 4's M axis. Deadline hits stay at 100% (hard constraints are never traded).");

    // ---- admission policies under deadline pressure ----
    // Tight betas: fixed windowing parks tight requests for the full wait;
    // the deadline-aware policy closes early enough to serve them in time.
    let tight_lo = args.get_f64("tight-beta-lo", 0.2)?;
    let tight_hi = args.get_f64("tight-beta-hi", 2.0)?;
    let mut rng = Rng::seed_from_u64(seed);
    let arrivals =
        poisson_arrivals(&ctx, 40.0, horizon.min(5.0), (tight_lo, tight_hi), &mut rng)?;
    let policies: Vec<Box<dyn AdmissionPolicy>> = vec![
        Box::new(TimeBound::new(window_ms / 1e3, 32)),
        Box::new(SizeBound::new(8)),
        Box::new(EarliestSlack::new(window_ms / 1e3, 32, 0.02)),
    ];
    println!(
        "\nadmission policies at 40 req/s, beta ~ U[{tight_lo},{tight_hi}] (tight deadlines):"
    );
    println!(
        "{:>16} {:>10} {:>12} {:>9} {:>12}",
        "policy", "windows", "mJ/req", "hit rate", "mean lat(ms)"
    );
    for row in online_policy_sweep(&ctx, &arrivals, &JDob::full(), policies) {
        println!(
            "{:>16} {:>10} {:>12.3} {:>8.1}% {:>12.2}",
            row.policy,
            row.stats.windows,
            row.stats.energy_per_user_j() * 1e3,
            100.0 * row.stats.hit_rate(),
            row.stats.mean_latency_s * 1e3,
        );
    }
    println!("\nthe same scheduler core serves all of this live: see `coordinator::server`,");
    println!("which pipelines planning of window k+1 against execution of window k.");
    Ok(())
}
